"""Multi-controller integration checks: 2 real processes x 4 devices each.

Drives ``scripts/launch_multihost.py`` (the exact entrypoint CI documents)
through the full failure matrix against a single-process 8-device
reference computed in this interpreter:

  A. uninterrupted 2-process run        -> bit-identical to partition_spmd
  B. kill worker 1 after the round-k snapshot published (job dies)
  C. resume B                           -> bit-identical, from round k
  D. kill worker 1 mid-save (shards staged, never published)
  E. resume D                           -> bit-identical, from round k-1
                                           (the torn round is skipped)
  F. single-process driver resumes A's 2-process snapshots (cross
     process-count restore compatibility)

Prints one ``RESULT {json}`` line and exits nonzero if any bit-identity
or protocol check fails, so it gates CI when run directly; the pytest
wrapper (tests/test_multihost.py, ``-m multihost``) asserts the same
fields for local runs.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", "")
)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import tempfile  # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np  # noqa: E402

ROOT = Path(__file__).resolve().parents[2]
SCRIPT = ROOT / "scripts" / "launch_multihost.py"
sys.path.insert(0, str(ROOT / "src"))

import jax  # noqa: E402

from repro.core import NEConfig  # noqa: E402
from repro.dist.partitioner_sm import partition_spmd  # noqa: E402
from repro.io.spill import spill_canonical_rmat  # noqa: E402
from repro.runtime import PartitionDriver  # noqa: E402

SCALE, EDGE_FACTOR = 10, 8
CFG = NEConfig(num_partitions=8, seed=0, k_sel=64, edge_chunk=1 << 12)

out = {"devices": len(jax.devices())}


def launch(td, name, extra, expect_fail=False):
    """One parent invocation of the launcher; returns (rc, out_dir)."""
    out_dir = td / f"out_{name}"
    args = [
        sys.executable,
        str(SCRIPT),
        "--edgefile",
        str(td / "graph" / "canonical.edges"),
        "--partitions",
        "8",
        "--seed",
        "0",
        "--k-sel",
        "64",
        "--edge-chunk",
        str(1 << 12),
        "--num-processes",
        "2",
        "--devices-per-process",
        "4",
        "--keep",
        "100000",
        "--log-dir",
        str(td / f"logs_{name}"),
        "--timeout",
        "900",
        *extra,
    ]
    if not expect_fail:
        args += ["--out", str(out_dir)]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        args, capture_output=True, text=True, timeout=1200, env=env
    )
    if not expect_fail and proc.returncode != 0:
        print(proc.stdout[-4000:], file=sys.stderr)
        print(proc.stderr[-4000:], file=sys.stderr)
        raise RuntimeError(f"run {name} failed rc={proc.returncode}")
    return proc.returncode, out_dir


def load(out_dir):
    res = np.load(out_dir / "result.npz")
    timing = json.loads((out_dir / "timing.json").read_text())
    return res, timing


def identical(res, ref):
    return bool(
        (res["edge_part"] == np.asarray(ref.edge_part)).all()
        and (res["vparts"] == np.asarray(ref.vparts)).all()
        and int(res["rounds"]) == int(ref.rounds)
    )


with tempfile.TemporaryDirectory() as _td:
    td = Path(_td)
    ef = spill_canonical_rmat(
        td / "graph", SCALE, EDGE_FACTOR, seed=3, chunk_size=1 << 12
    )
    out["num_edges"] = int(ef.num_edges)

    # single-process 8-device reference, same canonical EdgeFile
    ref = partition_spmd(ef, CFG)
    out["ref_rounds"] = int(ref.rounds)
    k = max(int(ref.rounds) // 2, 1)
    out["kill_round"] = k

    # A: uninterrupted 2-process run
    _, out_a = launch(
        td,
        "A",
        ["--snapshot-dir", str(td / "snapA"), "--snapshot-every", "1"],
    )
    res_a, timing_a = load(out_a)
    out["multihost_matches_spmd"] = identical(res_a, ref)
    out["multihost_rounds"] = int(res_a["rounds"])
    out["round_secs_mean"] = float(np.mean(timing_a["round_secs"][1:]))

    # B: worker 1 dies right after the round-k snapshot publishes
    rc_b, _ = launch(
        td,
        "B",
        [
            "--snapshot-dir",
            str(td / "snapB"),
            "--snapshot-every",
            "1",
            "--die-round",
            str(k),
            "--die-stage",
            "after-publish",
            "--die-process",
            "1",
        ],
        expect_fail=True,
    )
    out["kill_job_failed"] = rc_b != 0
    published_b = sorted(p.name for p in (td / "snapB").glob("step_*"))
    out["kill_last_published"] = (
        int(published_b[-1].split("_")[1]) if published_b else 0
    )

    # C: resume B — must replay rounds k+1..end bit-identically
    _, out_c = launch(
        td,
        "C",
        ["--snapshot-dir", str(td / "snapB"), "--resume"],
    )
    res_c, timing_c = load(out_c)
    out["resume_round"] = timing_c.get("resume_round")
    out["kill_resume_identical"] = identical(res_c, ref)

    # D: worker 1 dies mid-save — shards staged, manifest never published
    rc_d, _ = launch(
        td,
        "D",
        [
            "--snapshot-dir",
            str(td / "snapD"),
            "--snapshot-every",
            "1",
            "--die-round",
            str(k),
            "--die-stage",
            "after-shards",
            "--die-process",
            "1",
        ],
        expect_fail=True,
    )
    out["torn_job_failed"] = rc_d != 0
    published_d = sorted(p.name for p in (td / "snapD").glob("step_*"))
    out["torn_last_published"] = (
        int(published_d[-1].split("_")[1]) if published_d else 0
    )

    # E: resume D — the torn round k is skipped, resume starts at k-1
    _, out_e = launch(
        td,
        "E",
        ["--snapshot-dir", str(td / "snapD"), "--resume"],
    )
    res_e, timing_e = load(out_e)
    out["torn_resume_round"] = timing_e.get("resume_round")
    out["torn_resume_identical"] = identical(res_e, ref)

    # F: single-process driver restores the 2-process snapshots
    drv = PartitionDriver.resume(ef, CFG, td / "snapA")
    res_f = drv.run()
    out["crossproc_restore_identical"] = bool(
        (res_f.edge_part == ref.edge_part).all()
        and (res_f.vparts == ref.vparts).all()
    )
    ef.close()

out["kill_resume_round_correct"] = (
    out["kill_last_published"] == k and out["resume_round"] == k
)
out["torn_round_skipped"] = (
    out["torn_last_published"] == k - 1 and out["torn_resume_round"] == k - 1
)

CHECKS = [
    "multihost_matches_spmd",
    "kill_job_failed",
    "kill_resume_round_correct",
    "kill_resume_identical",
    "torn_job_failed",
    "torn_round_skipped",
    "torn_resume_identical",
    "crossproc_restore_identical",
]
out["ok"] = all(out[c] for c in CHECKS)
print("RESULT " + json.dumps(out))
if not out["ok"]:
    failed = [c for c in CHECKS if not out[c]]
    print(f"FAILED checks: {failed}", file=sys.stderr)
    raise SystemExit(1)
