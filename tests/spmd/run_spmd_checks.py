"""SPMD integration checks, run in a subprocess with 8 host devices.

Prints one JSON line with all results; the pytest wrapper asserts on it.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import json  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import NEConfig, evaluate, partition  # noqa: E402
from repro.dist import compat  # noqa: E402
from repro.dist.partitioner_sm import partition_spmd  # noqa: E402
from repro.apps.engine import build_sharded_graph  # noqa: E402
from repro.apps.algorithms import pagerank, sssp, wcc  # noqa: E402
from repro.graphs.generators import barabasi_albert  # noqa: E402
from repro.core.graph import to_networkx  # noqa: E402

out = {"devices": len(jax.devices())}

g = barabasi_albert(400, 3, seed=2)
e = np.asarray(g.edges)
cfg = NEConfig(num_partitions=8, seed=0, k_sel=64, edge_chunk=1 << 12)

# --- distributed partitioner vs single-controller --------------------------
res_sc = partition(g, cfg)
res_sm = partition_spmd(g, cfg)
st_sc = evaluate(e, res_sc.edge_part, g.num_vertices, 8)
st_sm = evaluate(e, res_sm.edge_part, g.num_vertices, 8)
out["rf_single"] = st_sc.replication_factor
out["rf_spmd"] = st_sm.replication_factor
out["eb_spmd"] = st_sm.edge_balance
out["spmd_all_assigned"] = bool((res_sm.edge_part >= 0).all())

# --- fused ne_round kernels + bit-packed replica sets: bit-identity --------
import dataclasses  # noqa: E402

cfg_pl = dataclasses.replace(cfg, use_pallas=True)
res_pl = partition_spmd(g, cfg_pl)
out["pallas_spmd_identical"] = bool(
    (res_pl.edge_part == res_sm.edge_part).all()
    and (res_pl.vparts == res_sm.vparts).all()
    and (res_pl.edges_per_part == res_sm.edges_per_part).all())
res_pl_sc = partition(g, dataclasses.replace(cfg, use_pallas=True))
out["pallas_single_identical"] = bool(
    (res_pl_sc.edge_part == res_sc.edge_part).all()
    and (res_pl_sc.vparts == res_sc.vparts).all())

# packed OR all-reduce == bool psum path, on the real 8-device mesh
from jax.sharding import PartitionSpec as PSpec  # noqa: E402
from repro.kernels.ne_round import ops as ne_ops  # noqa: E402

rng_or = np.random.default_rng(11)
bool_sh = rng_or.random((8, 128, 37)) < 0.1          # P=37: not 32-aligned
mesh_or = compat.make_mesh((8,), ("shard",))


def _or_body(b):
    words = ne_ops.pack_bits(b[0])
    red = compat.or_all_reduce(words, "shard", 8)
    return ne_ops.unpack_bits(red, 37)[None]


or_out = compat.shard_map(
    _or_body, mesh=mesh_or, in_specs=(PSpec("shard", None, None),),
    out_specs=PSpec("shard", None, None), check_vma=False,
)(jax.numpy.asarray(bool_sh))
out["pallas_or_reduce_ok"] = bool(
    (np.asarray(or_out) == bool_sh.any(axis=0)[None]).all())

# --- GAS engine apps vs networkx -------------------------------------------
sg = build_sharded_graph(e, res_sm.edge_part, g.num_vertices, 8)
gx = to_networkx(g)

import networkx as nx  # noqa: E402

pr = pagerank(sg, iters=40)
pr_nx = nx.pagerank(gx, alpha=0.85, max_iter=200, tol=1e-10)
pr_ref = np.array([pr_nx[i] for i in range(g.num_vertices)])
out["pr_max_err"] = float(np.abs(pr - pr_ref).max())

dist, it_s = sssp(sg, source=0)
d_nx = nx.single_source_shortest_path_length(gx, 0)
d_ref = np.full(g.num_vertices, np.inf)
for k, v in d_nx.items():
    d_ref[k] = v
finite = np.isfinite(d_ref)
out["sssp_match"] = bool((dist[finite] == d_ref[finite]).all())
out["sssp_iters"] = it_s

labels, it_w = wcc(sg)
comp_ref = {}
for i, comp in enumerate(nx.connected_components(gx)):
    m = min(comp)
    for v in comp:
        comp_ref[v] = m
lab_ref = np.array([comp_ref.get(i, -1) for i in range(g.num_vertices)])
has_edge = lab_ref >= 0
out["wcc_match"] = bool((labels[has_edge] == lab_ref[has_edge]).all())

# --- engine GNN forward == plain single-device forward ----------------------
import jax.numpy as jnp  # noqa: E402

from repro.launch import gnn_engine as ge  # noqa: E402
from repro.models.gnn import gin as gin_mod  # noqa: E402
from repro.models.gnn import egnn as egnn_mod  # noqa: E402
from repro.models.gnn import equiformer_v2 as eq_mod  # noqa: E402
from repro.models.gnn.common import GraphData, to_directed_padded  # noqa: E402

mesh = compat.make_mesh((8,), ("data",))
gsm = barabasi_albert(300, 3, seed=5)
esm = np.asarray(gsm.edges)
nsm = gsm.num_vertices
rng = np.random.default_rng(1)
feats = rng.normal(size=(nsm, 10)).astype(np.float32)
pos = rng.normal(size=(nsm, 3)).astype(np.float32)
labels = rng.integers(0, 3, nsm).astype(np.int32)
res_g = partition(gsm, NEConfig(num_partitions=8, seed=1, k_sel=32,
                                edge_chunk=1 << 12))
sg2 = build_sharded_graph(esm, res_g.edge_part, nsm, 8)

from repro.models.gnn import pna as pna_mod  # noqa: E402

for mod_name, mod, cfg in [
    ("gin", gin_mod, gin_mod.GINConfig(n_layers=2, d_hidden=16, d_feat=10,
                                       n_classes=3)),
    ("pna", pna_mod, pna_mod.PNAConfig(n_layers=2, d_hidden=16, d_feat=10,
                                       n_classes=3)),
    ("egnn", egnn_mod, egnn_mod.EGNNConfig(n_layers=2, d_hidden=16,
                                           d_feat=10, n_classes=3)),
    ("equiformer_v2", eq_mod, eq_mod.EquiformerV2Config(
        n_layers=1, d_hidden=8, l_max=2, m_max=2, n_heads=2, d_feat=10,
        n_classes=3)),
]:
    params = mod.init_params(jax.random.PRNGKey(2), cfg)
    caps = ge.caps_from_sharded_graph(sg2, 10, 3)
    arrays = ge.engine_arrays(sg2, feats, labels, np.ones(nsm, bool), pos)
    loss_eng = ge.make_engine_loss(mod_name, cfg, caps, mesh, ("data",),
                                   has_positions=True)(params, arrays)
    # plain single-device reference
    ei, em = to_directed_padded(esm, nsm)
    gref = GraphData(jnp.asarray(feats), jnp.asarray(ei), jnp.asarray(em),
                     positions=jnp.asarray(pos))
    logits = mod.forward(params, gref, cfg).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    oh = jax.nn.one_hot(labels, 3)
    loss_ref = (logz - (logits * oh).sum(-1)).mean()
    err = abs(float(loss_eng) - float(loss_ref))
    out[f"engine_{mod_name}_loss_err"] = err

# --- split-KV decode: seq-sharded cache == unsharded decode -----------------
from jax.sharding import NamedSharding  # noqa: E402

from repro.dist.sharding import lm_rules  # noqa: E402
from repro.models.lm import transformer as tfm  # noqa: E402

mesh2 = compat.make_mesh((2, 4), ("data", "model"))
lcfg = tfm.LMConfig(name="dec", n_layers=2, d_model=32, n_heads=8,
                    n_kv_heads=2, d_ff=64, vocab=64, head_dim=8,
                    dtype=jnp.float32, remat="none")
lp = tfm.init_params(jax.random.PRNGKey(3), lcfg)
smax = 32
kc = jax.random.normal(jax.random.PRNGKey(4),
                       (lcfg.n_layers, 1, smax, 2, 8)) * 0.3
vc = jax.random.normal(jax.random.PRNGKey(5),
                       (lcfg.n_layers, 1, smax, 2, 8)) * 0.3
tok = jnp.array([[7]], jnp.int32)
clen = jnp.int32(smax - 1)
ref_logits, _, _ = tfm.decode(lp, tok, (kc, vc), clen, lcfg)
# sharded: kv heads can't shard (2 < 4) → cache seq over both axes
rules = lm_rules(batch_axes=(), tp="model", q_ok=True, kv_ok=False,
                 seq_kv_axes=("data", "model"))
cache_sh = NamedSharding(mesh2, rules["kv_cache"])
with compat.set_mesh(mesh2):
    kc_s = jax.device_put(kc, cache_sh)
    vc_s = jax.device_put(vc, cache_sh)
    sh_logits, _, _ = jax.jit(
        lambda p, t, k, v, c: tfm.decode(p, t, (k, v), c, lcfg, rules)
    )(lp, tok, kc_s, vc_s, clen)
out["splitkv_decode_err"] = float(jnp.abs(sh_logits - ref_logits).max())

# --- MoE: explicit-EP shard_map path == dense dispatch path -----------------
from repro.dist.context import mesh_context  # noqa: E402
from repro.models.lm.moe import MoEConfig, init_moe, moe_block  # noqa: E402

# capacity_factor high enough that neither path drops tokens — dropping
# granularity (global vs per-shard positions) is the one designed divergence
mcfg = MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=4.0)
mp = init_moe(jax.random.PRNGKey(6), 24, mcfg, jnp.float32)
xm = jax.random.normal(jax.random.PRNGKey(7), (4, 6, 24))
y_dense, aux_dense = moe_block(mp, xm, mcfg, None)
with mesh_context(mesh2, batch_axes=("data",), model_axis="model"), \
        compat.set_mesh(mesh2):
    y_ep, aux_ep = jax.jit(lambda p, x: moe_block(p, x, mcfg, None))(mp, xm)
out["moe_ep_err"] = float(jnp.abs(y_dense - y_ep).max())
out["moe_aux_err"] = float(jnp.abs(aux_dense - aux_ep))

# --- all_to_all edge redistribution: partition p's edges land on device p ---
from repro.core.graph import shard_edges  # noqa: E402
from repro.dist.redistribute import redistribute_edges  # noqa: E402

shards_r, masks_r, _, dev_r = shard_edges(e, 8, salt=0)
parts_r = np.zeros(masks_r.shape, np.int32)
for dd in range(8):
    sel = np.nonzero(dev_r == dd)[0]
    parts_r[dd, : sel.size] = res_sm.edge_part[sel]
edges_out, mask_out, dropped = redistribute_edges(shards_r, masks_r,
                                                  parts_r)
ok_redis = dropped == 0
for dd in range(8):
    got = edges_out[dd][mask_out[dd]]
    want = e[res_sm.edge_part == dd]
    key_got = np.sort(got[:, 0].astype(np.int64) * 100000 + got[:, 1])
    key_want = np.sort(want[:, 0].astype(np.int64) * 100000 + want[:, 1])
    ok_redis &= key_got.tolist() == key_want.tolist()
out["redistribute_ok"] = bool(ok_redis)

# --- runtime driver: kill-at-round-k resume bit-identity on 8 devices -------
import tempfile  # noqa: E402

from repro.runtime import PartitionDriver, load_artifact  # noqa: E402

ne_cfg = NEConfig(num_partitions=8, seed=0, k_sel=64, edge_chunk=1 << 12)
with tempfile.TemporaryDirectory() as td:
    snap_dir = td + "/snap"
    drv = PartitionDriver(g, ne_cfg, snapshot_dir=snap_dir, snapshot_every=1,
                          keep=100_000)
    res_drv = drv.run()
    out["driver_matches_spmd"] = bool(
        (res_drv.edge_part == res_sm.edge_part).all()
        and (res_drv.vparts == res_sm.vparts).all()
        and res_drv.rounds == res_sm.rounds)
    k = max(res_drv.rounds // 2, 1)
    drv2 = PartitionDriver.resume(g, ne_cfg, snap_dir, round_k=k)
    res_back = drv2.run()
    out["driver_resume_identical"] = bool(
        (res_back.edge_part == res_drv.edge_part).all()
        and (res_back.vparts == res_drv.vparts).all())
    art = drv.save_artifact(td + "/art")
    loaded = load_artifact(td + "/art")
    out["artifact_roundtrip"] = bool(
        (loaded.edge_part == res_drv.edge_part).all()
        and (loaded.vparts == res_drv.vparts).all()
        and (loaded.edges == e).all())

print("RESULT " + json.dumps(out))
