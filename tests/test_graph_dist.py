"""Tests for the distribution helpers: 2D-hash edge sharding
(core/graph.py), the leftover cleanup pass, and edge redistribution."""
import numpy as np
import pytest

from repro.core import NEConfig, evaluate, theorem1_upper_bound
from repro.core.graph import grid_assign, shard_edges
from repro.core.partitioner import cleanup_leftovers
from repro.graphs.generators import erdos_renyi
from repro.graphs.rmat import rmat


@pytest.fixture(scope="module")
def graph():
    return rmat(10, 8, seed=3)


# ---------------------------------------------------------------------------
# grid_assign / shard_edges
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [1, 2, 4, 6, 8, 12, 16])
def test_grid_assign_in_range(graph, d):
    dev = np.asarray(grid_assign(graph.edges, d))
    assert dev.shape == (graph.num_edges,)
    assert (dev >= 0).all() and (dev < d).all()


def test_grid_assign_deterministic_and_salted(graph):
    a = np.asarray(grid_assign(graph.edges, 8, salt=0))
    b = np.asarray(grid_assign(graph.edges, 8, salt=0))
    c = np.asarray(grid_assign(graph.edges, 8, salt=1))
    np.testing.assert_array_equal(a, b)
    assert (a != c).any()          # a different salt moves some edges


def test_grid_assign_replica_locality(graph):
    """2D hash: a vertex's edges touch at most rows+cols distinct devices —
    the property that makes replica locations computable (paper §4)."""
    d = 16                          # 4×4 grid
    dev = np.asarray(grid_assign(graph.edges, d))
    e = np.asarray(graph.edges)
    deg = np.asarray(graph.degree)
    for v in deg.argsort()[-5:]:
        mask = (e[:, 0] == v) | (e[:, 1] == v)
        assert len(np.unique(dev[mask])) <= 2 * 4 - 1


@pytest.mark.parametrize("d", [1, 3, 8])
def test_shard_edges_roundtrip(graph, d):
    e = np.asarray(graph.edges)
    shards, masks, cap, dev = shard_edges(e, d)
    assert shards.shape == (d, cap, 2)
    assert masks.shape == (d, cap)
    # returned dev matches an independent grid_assign; capacity == max load
    np.testing.assert_array_equal(dev, np.asarray(grid_assign(graph.edges,
                                                              d)))
    counts = np.bincount(dev, minlength=d)
    assert cap == counts.max()
    np.testing.assert_array_equal(masks.sum(axis=1), counts)
    # invalid rows are zeroed
    assert (shards[~masks] == 0).all()
    # every edge appears exactly once across shards, none invented
    def key(x):
        return x[:, 0].astype(np.int64) * graph.num_vertices + x[:, 1]
    got = np.sort(np.concatenate([key(shards[i][masks[i]])
                                  for i in range(d)]))
    np.testing.assert_array_equal(got, np.sort(key(e)))


# ---------------------------------------------------------------------------
# cleanup_leftovers
# ---------------------------------------------------------------------------

def test_cleanup_respects_capacity_when_possible():
    m, p = 40, 4
    limit = 12                      # total capacity 48 > 40: all must fit
    edges = np.stack([np.arange(m), np.arange(m) + 1], axis=1)
    edge_part = np.full(m, -1, np.int32)
    edge_part[:20] = np.arange(20) % p
    counts = np.bincount(edge_part[:20], minlength=p).astype(np.int32)
    counts[0] = 11                  # partition 0 nearly full
    vparts = np.zeros((m + 1, p), bool)
    n_assigned = cleanup_leftovers(edge_part, vparts, counts, edges, p,
                                   limit)
    assert n_assigned == 20
    assert (edge_part >= 0).all()
    assert (counts <= limit).all()  # α-capacity respected — room existed
    # counts stays consistent with the assignment deltas
    np.testing.assert_array_equal(
        counts, np.bincount(edge_part, minlength=p) + [6, 0, 0, 0])


def test_cleanup_overflow_goes_least_loaded():
    m, p = 10, 2
    limit = 3                       # capacity 6 < 10: overflow unavoidable
    edges = np.stack([np.arange(m), np.arange(m) + 1], axis=1)
    edge_part = np.full(m, -1, np.int32)
    counts = np.array([3, 3], np.int32)   # both at capacity already
    vparts = np.zeros((m + 1, p), bool)
    cleanup_leftovers(edge_part, vparts, counts, edges, p, limit)
    assert (edge_part >= 0).all()
    assert abs(int(counts[0]) - int(counts[1])) <= 1  # balanced overflow


def test_cleanup_updates_replica_sets():
    edges = np.array([[0, 1], [2, 3]])
    edge_part = np.array([-1, -1], np.int32)
    counts = np.zeros(2, np.int32)
    vparts = np.zeros((4, 2), bool)
    cleanup_leftovers(edge_part, vparts, counts, edges, 2, limit=10)
    for eid in range(2):
        p = edge_part[eid]
        assert vparts[edges[eid, 0], p] and vparts[edges[eid, 1], p]


# ---------------------------------------------------------------------------
# partition_spmd + redistribute on however many host devices exist
# (the full 8-device run lives in tests/test_spmd.py's subprocess)
# ---------------------------------------------------------------------------

def test_partition_spmd_invariants_host():
    from repro.core.metrics import vertex_replicas
    from repro.dist.partitioner_sm import partition_spmd

    g = erdos_renyi(80, 4.0, seed=1)
    p = 4
    cfg = NEConfig(num_partitions=p, seed=0, k_sel=16, sel_chunk=2,
                   edge_chunk=256)
    res = partition_spmd(g, cfg)
    e = np.asarray(g.edges)
    assert res.edge_part.shape == (g.num_edges,)
    assert (res.edge_part >= 0).all() and (res.edge_part < p).all()
    np.testing.assert_array_equal(
        res.edges_per_part, np.bincount(res.edge_part, minlength=p))
    vr = vertex_replicas(e, res.edge_part, g.num_vertices, p)
    np.testing.assert_array_equal(res.vparts.sum(axis=0), vr)
    stats = evaluate(e, res.edge_part, g.num_vertices, p)
    assert stats.replication_factor <= \
        theorem1_upper_bound(g.num_vertices, g.num_edges, p) + 1e-9


@pytest.mark.parametrize("part_fn", ["partition", "partition_spmd"])
def test_leftover_hatch_via_public_api(part_fn):
    """max_rounds=1 forces the cleanup pass through both partitioners —
    regression for mutating read-only np views of jax outputs."""
    from repro.core import partition
    from repro.dist.partitioner_sm import partition_spmd

    g = erdos_renyi(60, 3.0, seed=2)
    cfg = NEConfig(num_partitions=4, seed=0, max_rounds=1, k_sel=8,
                   sel_chunk=2, edge_chunk=64)
    res = (partition if part_fn == "partition" else partition_spmd)(g, cfg)
    assert res.leftover > 0          # the hatch actually ran
    assert (res.edge_part >= 0).all()
    np.testing.assert_array_equal(
        res.edges_per_part, np.bincount(res.edge_part, minlength=4))


def test_redistribute_numpy_reference():
    from repro.dist.redistribute import redistribute_edges

    rng = np.random.default_rng(0)
    d, c = 4, 7
    shards = rng.integers(0, 50, (d, c, 2)).astype(np.int32)
    masks = rng.random((d, c)) < 0.8
    parts = rng.integers(-1, d, (d, c)).astype(np.int32)  # some invalid
    edges_out, mask_out, dropped = redistribute_edges(shards, masks, parts)
    valid = masks & (parts >= 0) & (parts < d)
    assert dropped == int(masks.sum() - valid.sum())
    # each device receives exactly the rows targeted at it
    for dd in range(d):
        got = edges_out[dd][mask_out[dd]]
        want = np.concatenate([shards[s][valid[s] & (parts[s] == dd)]
                               for s in range(d)])
        np.testing.assert_array_equal(got, want)
